"""Cost-driven heterogeneous graph partitioning (paper Sec. IV-B).

The paper's claim (Table IV "Full") is that choosing *which execution
module runs each graph segment* jointly — NE16 and the 8-core cluster on
the same network — beats any single-accelerator mapping.  This module
implements that decision as a **DP shortest path over the graph IR**
rather than the greedy per-node walk of early MATCH/HTVM flows:

1. *Candidate enumeration* — every pattern match of every module's
   pattern table anchored at every node (all fusion lengths, not just the
   largest), plus the target's fallback module per node.
2. *Batched DSE* — all (workload, module) LOMA queries are collected,
   deduped by geometry key and evaluated through a
   :class:`~repro.core.loma.SchedulePlanner` (thread pool + optional
   persistent JSON cache, so a warm re-compile skips the search).
3. *Transfer-aware DP* — a Viterbi-style pass over the topological order
   picks the segmentation *and* the module assignment minimising
   ``sum(segment cycles) + sum(cross-module transfer cycles)``, where
   transfers are priced by :func:`~repro.core.cost_model.transfer_cost`
   from the edge's activation bytes and the target's
   :class:`~repro.core.target.Interconnect`.  The DP state at a segment
   boundary is the module of every still-live producer edge — exact on
   chains and on the bounded-width residual branches of the MLPerf-Tiny
   nets, beam-limited (``beam``) when branch points proliferate.

``dispatch(graph, target)`` keeps its `MappedGraph` contract for
``cnn/execute.py``, ``examples/`` and ``benchmarks/``; the old greedy
policy survives as ``dispatch(..., policy="greedy")`` for baselines (its
result is annotated with the same transfer accounting so predicted
latencies stay comparable).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from repro import obs

from .cost_model import evaluate_mapping, transfer_cost
from .graph import Graph, Node
from .loma import SchedulePlanner, ScheduleResult, TemporalMapping, search_schedule
from .patterns import PatternMatch, default_workload, find_matches
from .target import ExecutionModule, MatchTarget
from .workload import Workload

__all__ = ["MappedSegment", "MappedGraph", "dispatch"]


@dataclass(frozen=True)
class MappedSegment:
    """A fused group of nodes mapped onto one execution module."""

    nodes: tuple[Node, ...]
    module: str
    schedule: ScheduleResult | None  # None for zero-cost structural ops
    workload: Workload | None
    pattern: str = ""
    # cycles to bring this segment's external inputs across a module
    # boundary (0 when every producer ran on the same module)
    transfer_cycles: float = 0.0

    @property
    def cycles(self) -> float:
        if self.schedule is None:
            return 0.0
        return self.schedule.latency_cycles

    @property
    def total_cycles(self) -> float:
        return self.cycles + self.transfer_cycles

    @property
    def anchor(self) -> Node:
        return self.nodes[0]

    # -- lowering metadata (consumed by repro.backend) ------------------
    @property
    def output_node(self) -> Node:
        """The node whose tensor leaves the segment (fusion chains are
        single-consumer, so only the last node is externally visible)."""
        return self.nodes[-1]

    @property
    def epilogue(self) -> tuple[Node, ...]:
        """The fused nodes after the anchor (bias/requant/relu chains)."""
        return self.nodes[1:]

    def external_inputs(self, graph: Graph) -> tuple[str, ...]:
        """Producer names feeding this segment from outside it, in first-use
        order (graph inputs included) — the executor's argument order."""
        inside = {n.name for n in self.nodes}
        out: list[str] = []
        for n in self.nodes:
            for inp in n.inputs:
                if inp not in inside and inp not in out:
                    out.append(inp)
        return tuple(out)


@dataclass
class MappedGraph:
    """Dispatch result: full partitioning of a graph over a target."""

    graph: Graph
    target: MatchTarget
    segments: list[MappedSegment]
    attrs: dict = field(default_factory=dict)

    def total_cycles(self) -> float:
        """Predicted end-to-end cycles, cross-module transfers included."""
        return sum(s.total_cycles for s in self.segments)

    def compute_cycles(self) -> float:
        return sum(s.cycles for s in self.segments)

    def transfer_cycles(self) -> float:
        return sum(s.transfer_cycles for s in self.segments)

    def latency_s(self, frequency_hz: float | None = None) -> float:
        f = frequency_hz or self.target.fallback.frequency_hz
        return self.total_cycles() / f

    def cycles_by_module(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for s in self.segments:
            out[s.module] = out.get(s.module, 0.0) + s.cycles
        return out

    def module_of(self, node_name: str) -> str:
        for s in self.segments:
            if any(n.name == node_name for n in s.nodes):
                return s.module
        raise KeyError(node_name)

    def macs_per_cycle(self) -> float:
        macs = self.graph.total_macs()
        cyc = self.total_cycles()
        return macs / cyc if cyc > 0 else 0.0

    def summary(self) -> str:
        lines = [f"MappedGraph[{self.graph.name} on {self.target.name}]"]
        for s in self.segments:
            names = "+".join(n.name for n in s.nodes)
            xfer = f" +{s.transfer_cycles:.0f} xfer" if s.transfer_cycles else ""
            lines.append(
                f"  {names:<40s} -> {s.module:<10s} {s.cycles:>14.0f} cyc{xfer}"
                + (f"  ({s.pattern})" if s.pattern else "")
            )
        lines.append(
            f"  TOTAL {self.total_cycles():.0f} cycles"
            f" ({self.transfer_cycles():.0f} in transfers),"
            f" {self.macs_per_cycle():.2f} MACs/cyc"
        )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------


@dataclass
class _Candidate:
    """One (segment, module) option anchored at a topological position."""

    nodes: tuple[Node, ...]
    module: ExecutionModule
    workload: Workload | None
    pattern: str
    schedule: ScheduleResult | None = None

    @property
    def cycles(self) -> float:
        return self.schedule.latency_cycles if self.schedule is not None else 0.0


def _untiled_stream_schedule(wl: Workload, module: ExecutionModule) -> ScheduleResult:
    """The always-feasible 'stream every element' mapping for the fallback
    CPU — the paper's un-matched -> plain TVM path must never fail."""
    tiles = {l.name: 1 for l in wl.loops}
    cost = evaluate_mapping(wl, tiles, tuple(wl.dim_names), module)
    return ScheduleResult(wl.name, module.name, TemporalMapping(tiles, tuple(wl.dim_names)), cost, 1)


def _enumerate_candidates(
    graph: Graph,
    target: MatchTarget,
    planner: SchedulePlanner,
    budget: int,
) -> list[list[_Candidate]]:
    """All candidate segments per topo position + registered DSE queries.

    Matches are kept only when their node chain is contiguous in the topo
    order (true for single-consumer fusion chains built by the netlists),
    which keeps the DP a clean segmentation over the node list.  Each
    position always retains the fallback candidate so the DP never dead-ends.
    """
    nodes = graph.nodes
    cands: list[list[_Candidate]] = [[] for _ in nodes]
    for i, node in enumerate(nodes):
        for module in target.modules:
            for m in find_matches(graph, node, module.patterns):
                if m.nodes != tuple(nodes[i : i + len(m.nodes)]):
                    continue  # non-contiguous chain: not a DP segment
                wl = m.workload()  # built once: reused for DSE + the segment
                planner.request(wl, module, budget=budget)
                cands[i].append(_Candidate(m.nodes, module, wl, m.pattern.name))
        wl = default_workload(node)
        if wl is not None:
            planner.request(wl, target.fallback, budget=budget)
            cands[i].append(_Candidate((node,), target.fallback, wl, "fallback"))
        else:
            # structural ops (reshape, ...) cost ~0 on *any* module: offer
            # every placement so the DP can keep them transfer-transparent
            # inside a same-module run instead of pinning them to the CPU
            # and pricing phantom round trips on both sides.
            for module in target.all_modules():
                cands[i].append(_Candidate((node,), module, None, "structural"))
    return cands


def _resolve_schedules(
    cands: list[list[_Candidate]],
    planner: SchedulePlanner,
    budget: int,
) -> list[list[_Candidate]]:
    """Attach DSE results; drop infeasible matches, rescue the fallback."""
    out: list[list[_Candidate]] = []
    for options in cands:
        kept: list[_Candidate] = []
        for c in options:
            if c.workload is None:
                kept.append(c)  # structural: zero cost by construction
                continue
            sched = planner.get(c.workload, c.module, budget=budget)
            if not sched.feasible:
                if c.pattern == "fallback":
                    sched = _untiled_stream_schedule(c.workload, c.module)
                else:
                    continue
            c.schedule = sched
            kept.append(c)
        out.append(kept)
    return out


# ---------------------------------------------------------------------------
# Transfer accounting
# ---------------------------------------------------------------------------


def _external_inputs(graph: Graph, seg_nodes: Sequence[Node]) -> dict[str, int]:
    """producer-name -> edge bytes for inputs produced outside the segment
    by another graph node (graph inputs live in shared memory already)."""
    inside = {n.name for n in seg_nodes}
    edges: dict[str, int] = {}
    for n in seg_nodes:
        for inp in n.inputs:
            if inp in inside or not graph.has(inp):
                continue
            edges[inp] = graph.edge_bytes(inp)
    return edges


def _edges_transfer(
    edges: dict[str, int],
    module: ExecutionModule,
    mod_of: dict[str, str],
    target: MatchTarget,
    modmap: dict[str, ExecutionModule],
) -> float:
    total = 0.0
    for producer, nbytes in edges.items():
        src = modmap[mod_of[producer]]
        total += transfer_cost(nbytes, src, module, target.interconnect)
    return total


def _segment_transfer(
    graph: Graph,
    seg_nodes: Sequence[Node],
    module: ExecutionModule,
    mod_of: dict[str, str],
    target: MatchTarget,
    modmap: dict[str, ExecutionModule],
) -> float:
    return _edges_transfer(_external_inputs(graph, seg_nodes), module, mod_of, target, modmap)


# ---------------------------------------------------------------------------
# The DP (Viterbi) partitioner
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _State:
    cost: float
    segments: tuple[MappedSegment, ...]
    mod_of: dict  # node name -> module name for every covered node


# complete segmentations the DP keeps for makespan re-ranking: enough
# beam survivors that a sum-suboptimal but overlap-friendly mapping is
# still on the table, small enough that scheduling them all is free
_FINALS_KEPT = 64

# requests in the synthetic unit-weight stream the "wct" objective prices
# each candidate segmentation against: deep enough that the steady-state
# initiation interval dominates (C_k ~ makespan + (k-1)*II, so the sum
# weighs II (depth-1)/2 times per request), shallow enough to stay free
_WCT_STREAM_DEPTH = 4


def _dispatch_dp(
    graph: Graph,
    target: MatchTarget,
    planner: SchedulePlanner,
    budget: int,
    beam: int,
    verbose: bool,
    objective: str = "cycles",
) -> MappedGraph:
    nodes = graph.nodes
    n = len(nodes)
    if n == 0:
        return MappedGraph(graph, target, [])

    with obs.span("dispatch.enumerate", cat="compile") as sp:
        cands = _enumerate_candidates(graph, target, planner, budget)
        sp.set(positions=n, candidates=sum(len(c) for c in cands))
    stats0 = dict(planner.stats)
    with obs.span("dispatch.dse_flush", cat="compile") as sp:
        planner.flush()
        # cache hit/miss attribution for this dispatch: the planner is
        # shared across compiles, so report the delta, not the totals
        sp.set(**{k: planner.stats[k] - stats0.get(k, 0) for k in planner.stats})
    with obs.span("dispatch.resolve", cat="compile"):
        cands = _resolve_schedules(cands, planner, budget)

    modmap = {m.name: m for m in target.all_modules()}

    # last topo position that still consumes each node's output
    last_use = {nd.name: -1 for nd in nodes}
    for i, nd in enumerate(nodes):
        for inp in nd.inputs:
            if inp in last_use:
                last_use[inp] = max(last_use[inp], i)
    # live[j]: producers whose edge crosses segment boundary j
    live: list[tuple[str, ...]] = [()] * (n + 1)
    for j in range(1, n + 1):
        live[j] = tuple(
            nd.name for nd in nodes[:j] if last_use[nd.name] >= j
        )

    def state_key(j: int, mod_of: dict) -> tuple:
        return tuple((p, mod_of[p]) for p in live[j])

    states: list[dict[tuple, _State]] = [dict() for _ in range(n + 1)]
    states[0][()] = _State(0.0, (), {})
    # complete segmentations keyed by (boundaries, modules): the state key
    # at position n collapses to () (nothing stays live), which would keep
    # exactly one survivor — the makespan objective needs the runners-up.
    # Under objective="cycles" only the running minimum is kept (no
    # signature bookkeeping in the DP hot loop).
    track_finals = objective in ("makespan", "wct")
    finals: dict[tuple, _State] = {}
    best_final: _State | None = None

    viterbi_span = obs.span("dispatch.viterbi", cat="compile", nodes=n, beam=beam)
    viterbi_span.__enter__()
    for i in range(n):
        here = states[i]
        if not here:
            continue
        ranked = sorted(here.values(), key=lambda s: s.cost)[: max(1, beam)]
        for c in cands[i]:
            # the producer -> bytes map is state-independent: hoist it out
            # of the beam loop (only the per-producer module varies)
            edges = _external_inputs(graph, c.nodes)
            for st in ranked:
                j = i + len(c.nodes)
                xfer = _edges_transfer(edges, c.module, st.mod_of, target, modmap)
                seg = MappedSegment(
                    c.nodes,
                    c.module.name,
                    c.schedule,
                    c.workload,
                    pattern=c.pattern,
                    transfer_cycles=xfer,
                )
                cost = st.cost + seg.cycles + xfer
                mod_of = dict(st.mod_of)
                for nd in c.nodes:
                    mod_of[nd.name] = c.module.name
                key = state_key(j, mod_of)
                cur = states[j].get(key)
                if cur is None or cost < cur.cost:
                    states[j][key] = _State(cost, st.segments + (seg,), mod_of)
                if j == n:
                    if track_finals:
                        done = _State(cost, st.segments + (seg,), mod_of)
                        sig = tuple(
                            (s.anchor.name, s.module, len(s.nodes))
                            for s in done.segments
                        )
                        old = finals.get(sig)
                        if old is None or done.cost < old.cost:
                            finals[sig] = done
                    elif best_final is None or cost < best_final.cost:
                        best_final = _State(cost, st.segments + (seg,), mod_of)

    viterbi_span.set(final_states=len(finals) if track_finals else 1).__exit__(
        None, None, None
    )

    attrs = {"policy": "dp", "objective": objective, "planner_stats": dict(planner.stats)}
    if track_finals:
        # re-rank the surviving complete segmentations by a schedule-level
        # objective: "makespan" scores the concurrent single-input
        # schedule; "wct" scores the weighted completion time of a
        # unit-weight request stream (repro.pipeline.schedule_stream), so
        # a serving-friendly segmentation — one whose steady-state
        # initiation interval, not just its latency, is small — wins.
        # Ties fall back to makespan then the cycle sum, so chains with
        # no overlap opportunity reproduce the cycles objective.
        from repro.pipeline.schedule import (  # no cycle: late import
            schedule_pipeline,
            schedule_stream,
        )

        with obs.span("dispatch.makespan_rerank", cat="compile") as sp:
            ranked = sorted(finals.values(), key=lambda s: s.cost)[:_FINALS_KEPT]
            best: _State | None = None
            best_key: tuple[float, ...] | None = None
            best_span: float = 0.0
            for st in ranked:
                mg = MappedGraph(graph, target, list(st.segments))
                ps = schedule_pipeline(mg)
                if objective == "wct":
                    ss = schedule_stream(mg, (1.0,) * _WCT_STREAM_DEPTH)
                    key = (ss.attrs["weighted_completion"], ps.makespan, st.cost)
                else:
                    key = (ps.makespan, st.cost)
                if best_key is None or key < best_key:
                    best, best_key, best_span = st, key, ps.makespan
            final = best
            sp.set(candidates=len(ranked), makespan=best_span)
        attrs["predicted_makespan"] = best_span
        attrs["candidates_reranked"] = len(ranked)
        if objective == "wct":
            attrs["predicted_weighted_completion"] = best_key[0]
            attrs["wct_stream_depth"] = _WCT_STREAM_DEPTH
    else:
        final = best_final
    if verbose:
        for s in final.segments:
            print(
                f"  dispatch {s.anchor.name} -> {s.module}"
                f" ({s.cycles:.0f} cyc + {s.transfer_cycles:.0f} xfer)"
            )
    return MappedGraph(graph, target, list(final.segments), attrs=attrs)


# ---------------------------------------------------------------------------
# Greedy baseline (the seed policy, kept for ablation benchmarks)
# ---------------------------------------------------------------------------


def _fallback_segment(
    target: MatchTarget, nodes: tuple[Node, ...], budget: int
) -> MappedSegment:
    wl = default_workload(nodes[0]) if len(nodes) == 1 else None
    if wl is None:
        return MappedSegment(nodes, target.fallback.name, None, None, pattern="structural")
    sched = search_schedule(wl, target.fallback, budget=budget)
    if not sched.feasible:
        sched = _untiled_stream_schedule(wl, target.fallback)
    return MappedSegment(nodes, target.fallback.name, sched, wl, pattern="fallback")


def _dispatch_greedy(
    graph: Graph, target: MatchTarget, budget: int, verbose: bool
) -> MappedGraph:
    """Largest-match-first, transfer-blind per-node walk (HTVM-style)."""
    segments: list[MappedSegment] = []
    consumed: set[str] = set()

    for node in graph.nodes:
        if node.name in consumed:
            continue

        per_module: list[tuple[ExecutionModule, PatternMatch]] = []
        for module in target.modules:
            for m in find_matches(graph, node, module.patterns):
                per_module.append((module, m))

        chosen: MappedSegment | None = None
        if per_module:
            max_len = max(len(m.nodes) for _, m in per_module)
            for length in range(max_len, 0, -1):
                cands = [(mod, m) for mod, m in per_module if len(m.nodes) == length]
                best: tuple[ExecutionModule, PatternMatch, Workload, ScheduleResult] | None = None
                for mod, m in cands:
                    wl = m.workload()  # built once per match
                    sched = search_schedule(wl, mod, budget=budget)
                    if not sched.feasible:
                        continue
                    if best is None or sched.latency_cycles < best[3].latency_cycles:
                        best = (mod, m, wl, sched)
                if best is not None:
                    mod, m, wl, sched = best
                    chosen = MappedSegment(m.nodes, mod.name, sched, wl, pattern=m.pattern.name)
                    break

        if chosen is None:
            chosen = _fallback_segment(target, (node,), budget)

        segments.append(chosen)
        consumed |= {n.name for n in chosen.nodes}
        if verbose:
            print(f"  dispatch {chosen.anchor.name} -> {chosen.module} ({chosen.cycles:.0f} cyc)")

    # annotate the greedy result with the same transfer accounting the DP
    # optimises, so predicted latencies are directly comparable
    modmap = {m.name: m for m in target.all_modules()}
    mod_of = {n.name: s.module for s in segments for n in s.nodes}
    import dataclasses

    annotated = [
        dataclasses.replace(
            s,
            transfer_cycles=_segment_transfer(
                graph, s.nodes, modmap[s.module], mod_of, target, modmap
            ),
        )
        for s in segments
    ]
    return MappedGraph(graph, target, annotated, attrs={"policy": "greedy"})


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


# "no profile argument given" (mirrors repro.targets.registry): the
# MATCH_CALIBRATION_PROFILE env default may apply; ``profile=None``
# explicitly forces the declared (uncalibrated) model.
_PROFILE_UNSET = object()


def dispatch(
    graph: Graph,
    target: MatchTarget | str,
    *,
    budget: int = 4000,
    policy: str = "dp",
    objective: str = "cycles",
    beam: int = 12,
    planner: SchedulePlanner | None = None,
    cache_path=None,
    profile=_PROFILE_UNSET,
    verbose: bool = False,
) -> MappedGraph:
    """Partition ``graph`` across ``target``'s execution modules.

    ``target`` is a :class:`MatchTarget` or a registered target *name*
    (resolved through :mod:`repro.targets.registry` — the agile
    retargeting entry point).
    ``policy="dp"`` (default) runs the transfer-aware DP partitioner;
    ``policy="greedy"`` keeps the legacy largest-match walk as a baseline.
    ``objective`` selects what the DP minimises: ``"cycles"`` (default)
    keeps the sequential sum of compute + transfer cycles;
    ``"makespan"`` re-ranks the DP's surviving complete segmentations by
    their *concurrently scheduled* makespan
    (:func:`repro.pipeline.schedule.schedule_pipeline` — each execution
    module a resource with its own clock), so independent branches are
    worth spreading across modules.  Ties fall back to the cycle sum,
    which keeps skipless chains identical under both objectives.
    ``"wct"`` extends the makespan re-rank to *serving*: candidates are
    scored by the weighted completion time of a unit-weight request
    stream (:func:`repro.pipeline.schedule.schedule_stream`), which
    prices the steady-state initiation interval on top of the one-shot
    latency — the segmentation a loaded replica should run.
    ``planner`` / ``cache_path`` control schedule batching and the
    persistent DSE cache (see :class:`~repro.core.loma.SchedulePlanner`).
    ``profile`` applies a :class:`~repro.calibrate.CalibrationProfile`
    (or a path to one) on top of the declared target, so the DSE ranks
    candidates with measured — not assumed — hardware constants; for
    target *names* it follows ``get_target`` semantics (omitted = the
    ``MATCH_CALIBRATION_PROFILE`` env default, ``None`` = explicitly
    uncalibrated), while a :class:`MatchTarget` *instance* is taken
    as-is unless a profile is explicitly passed (the env default never
    mutates an instance the caller built).  A profile fitted for a
    different target is rejected with :class:`ValueError` on both paths.
    """
    if isinstance(target, str):
        # late import: repro.targets depends on repro.core, not vice versa
        # (and an explicit MatchTarget instance must keep working even if
        # the targets package cannot import)
        from repro.targets.registry import get_target

        if profile is _PROFILE_UNSET:
            target = get_target(target)
        else:
            target = get_target(target, profile=profile)
    elif profile is not _PROFILE_UNSET and profile is not None:
        from repro.calibrate.profile import (
            apply_profile,
            coerce_profile,
            profile_matches_target,
        )

        prof = coerce_profile(profile)
        if prof is not None and not profile_matches_target(prof, target.name):
            raise ValueError(
                f"calibration profile is for target {prof.target!r}, "
                f"not {target.name!r}"
            )
        target = apply_profile(target, prof)
    if objective not in ("cycles", "makespan", "wct"):
        raise ValueError(f"unknown dispatch objective {objective!r}")
    if policy == "greedy":
        if planner is not None or cache_path is not None:
            raise ValueError(
                "policy='greedy' searches serially and does not use the "
                "schedule planner; drop planner=/cache_path= (DP only)"
            )
        if objective != "cycles":
            raise ValueError(
                "policy='greedy' picks segments locally and cannot optimise "
                "a schedule-level objective; use policy='dp' for makespan"
            )
        return _dispatch_greedy(graph, target, budget, verbose)
    if policy != "dp":
        raise ValueError(f"unknown dispatch policy {policy!r}")
    if planner is not None and cache_path is not None:
        raise ValueError(
            "pass either planner= (already bound to its cache file) or "
            "cache_path= (a planner is created for you), not both"
        )
    if planner is None:
        planner = SchedulePlanner(cache_path=cache_path)
    obs.counter("dispatch.calls").inc()
    with obs.span(
        "dispatch", cat="compile",
        graph=graph.name, target=target.name, objective=objective,
    ):
        return _dispatch_dp(graph, target, planner, budget, beam, verbose, objective)
