"""Heterogeneous accelerator-aware dispatch (paper Sec. IV-B).

For every graph segment, all execution modules whose pattern tables match
are costed through the LOMA DSE; the module with the minimum predicted
latency wins the segment.  Unmatched (or nowhere-feasible) segments fall
back to the target's fallback module — the "un-matched -> TVM default on
the main CPU" path of the paper.

This is the piece missing from DORY/HTVM that the paper highlights: on
GAP9 it lets the NE16 accelerator and the 8-core cluster be used *on the
same network*, each where it is fastest (Table IV "Full" column).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .graph import Graph, Node
from .loma import ScheduleResult, search_schedule
from .patterns import PatternMatch, default_workload, find_matches
from .target import ExecutionModule, MatchTarget
from .workload import Workload

__all__ = ["MappedSegment", "MappedGraph", "dispatch"]


@dataclass(frozen=True)
class MappedSegment:
    """A fused group of nodes mapped onto one execution module."""

    nodes: tuple[Node, ...]
    module: str
    schedule: ScheduleResult | None  # None for zero-cost structural ops
    workload: Workload | None
    pattern: str = ""

    @property
    def cycles(self) -> float:
        if self.schedule is None:
            return 0.0
        return self.schedule.latency_cycles

    @property
    def anchor(self) -> Node:
        return self.nodes[0]


@dataclass
class MappedGraph:
    """Dispatch result: full partitioning of a graph over a target."""

    graph: Graph
    target: MatchTarget
    segments: list[MappedSegment]

    def total_cycles(self) -> float:
        return sum(s.cycles for s in self.segments)

    def latency_s(self, frequency_hz: float | None = None) -> float:
        f = frequency_hz or self.target.fallback.frequency_hz
        return self.total_cycles() / f

    def cycles_by_module(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for s in self.segments:
            out[s.module] = out.get(s.module, 0.0) + s.cycles
        return out

    def module_of(self, node_name: str) -> str:
        for s in self.segments:
            if any(n.name == node_name for n in s.nodes):
                return s.module
        raise KeyError(node_name)

    def macs_per_cycle(self) -> float:
        macs = self.graph.total_macs()
        cyc = self.total_cycles()
        return macs / cyc if cyc > 0 else 0.0

    def summary(self) -> str:
        lines = [f"MappedGraph[{self.graph.name} on {self.target.name}]"]
        for s in self.segments:
            names = "+".join(n.name for n in s.nodes)
            lines.append(
                f"  {names:<40s} -> {s.module:<10s} {s.cycles:>14.0f} cyc"
                + (f"  ({s.pattern})" if s.pattern else "")
            )
        lines.append(f"  TOTAL {self.total_cycles():.0f} cycles, {self.macs_per_cycle():.2f} MACs/cyc")
        return "\n".join(lines)


def _fallback_segment(
    target: MatchTarget, nodes: tuple[Node, ...], budget: int
) -> MappedSegment:
    wl = default_workload(nodes[0]) if len(nodes) == 1 else None
    if wl is None:
        return MappedSegment(nodes, target.fallback.name, None, None, pattern="structural")
    sched = search_schedule(wl, target.fallback, budget=budget)
    if not sched.feasible:
        # the fallback CPU must always execute: model as untiled streaming
        from .cost_model import evaluate_mapping
        from .loma import TemporalMapping

        tiles = {l.name: 1 for l in wl.loops}
        cost = evaluate_mapping(wl, tiles, tuple(wl.dim_names), target.fallback)
        sched = ScheduleResult(wl.name, target.fallback.name, TemporalMapping(tiles, tuple(wl.dim_names)), cost, 1)
    return MappedSegment(nodes, target.fallback.name, sched, wl, pattern="fallback")


def dispatch(
    graph: Graph,
    target: MatchTarget,
    *,
    budget: int = 4000,
    verbose: bool = False,
) -> MappedGraph:
    """Partition ``graph`` across ``target``'s execution modules.

    Paper Sec. IV-B: iterate the pattern tables of every module; for nested
    patterns keep the largest; for a pattern supported by several modules,
    DSE each and keep the minimum-predicted-latency module; unmatched ->
    fallback.
    """
    segments: list[MappedSegment] = []
    consumed: set[str] = set()

    for node in graph.nodes:
        if node.name in consumed:
            continue

        # gather matches from every module's pattern table
        per_module: list[tuple[ExecutionModule, PatternMatch]] = []
        for module in target.modules:
            for m in find_matches(graph, node, module.patterns):
                per_module.append((module, m))

        chosen: MappedSegment | None = None
        if per_module:
            # largest-match-first (fusion always convenient), then cost argmin
            max_len = max(len(m.nodes) for _, m in per_module)
            for length in range(max_len, 0, -1):
                cands = [(mod, m) for mod, m in per_module if len(m.nodes) == length]
                best: tuple[ExecutionModule, PatternMatch, ScheduleResult] | None = None
                for mod, m in cands:
                    wl = m.workload()
                    sched = search_schedule(wl, mod, budget=budget)
                    if not sched.feasible:
                        continue
                    if best is None or sched.latency_cycles < best[2].latency_cycles:
                        best = (mod, m, sched)
                if best is not None:
                    mod, m, sched = best
                    chosen = MappedSegment(m.nodes, mod.name, sched, m.workload(), pattern=m.pattern.name)
                    break

        if chosen is None:
            chosen = _fallback_segment(target, (node,), budget)

        segments.append(chosen)
        consumed |= {n.name for n in chosen.nodes}
        if verbose:
            print(f"  dispatch {chosen.anchor.name} -> {chosen.module} ({chosen.cycles:.0f} cyc)")

    return MappedGraph(graph, target, segments)
