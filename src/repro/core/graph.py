"""Lightweight operator-graph IR (the Relay analogue of paper Sec. IV-A).

MATCH consumes DNNs as graphs of high-level tensor ops.  In the paper the
graph is TVM Relay; here it is a minimal topologically-ordered node list —
enough to express the MLPerf-Tiny CNNs and per-block LM layer graphs, to
run HW-agnostic / HW-aware transformation passes over, and to pattern-match
against execution-module pattern tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Iterable, Mapping, Sequence

__all__ = ["Node", "Graph", "GraphTransform", "apply_transforms", "PASSTHROUGH_OPS"]

# Structural ops whose output is (a view of) their first input: element
# count AND element width are preserved, so a transfer edge out of them
# is as big as the tensor flowing *through* them.  (A width-changing
# ``cast`` deliberately does not qualify: pricing it with the producer's
# elem_bytes would mis-size the edge.)
PASSTHROUGH_OPS = ("reshape", "flatten", "squeeze", "expand_dims", "identity")


@dataclass(frozen=True)
class Node:
    """One operation over tensors.

    ``op``: operator type, e.g. ``conv2d``, ``dwconv2d``, ``dense``,
    ``add``, ``avgpool``, ``maxpool``, ``relu``, ``requant``, ``bias_add``,
    ``softmax``, ``reshape``, ``matmul``, ``attention``, ``moe_ffn``,
    ``rglru``, ``ssd`` ...
    ``inputs``: names of producer nodes (or graph inputs).
    ``attrs``: operator hyper-parameters (paper notation for convs:
    K/C/OY/OX/FY/FX/stride, plus dtype bytes).
    """

    name: str
    op: str
    inputs: tuple[str, ...] = ()
    attrs: Mapping[str, object] = field(default_factory=dict)

    def attr(self, key: str, default=None):
        return self.attrs.get(key, default)

    def with_attrs(self, **kw) -> "Node":
        a = dict(self.attrs)
        a.update(kw)
        return replace(self, attrs=a)

    # -- output tensor sizing (used to size cross-module transfers) -----
    def has_geometry(self) -> bool:
        """True when the node carries tensor-shape attrs (K/C/OY/OX).

        Structural ops (reshape, ...) usually don't; their real output
        size is their producer's, which ``Graph.edge_bytes`` resolves by
        walking the passthrough chain."""
        return any(self.attr(k) for k in ("K", "C", "OY", "OX"))

    def output_elems(self) -> int:
        """Elements of this node's output tensor, from geometry attrs.

        Convs/denses produce B x K x OY x OX; depthwise convs, pools and
        elementwise ops keep the channel count C.  A node without geometry
        reports 1 element — callers that know the graph should size such
        edges via ``Graph.edge_bytes``, which propagates the producing
        tensor's true size through structural passthrough chains.
        """
        b = int(self.attr("B", 1) or 1)
        ch = int(self.attr("K", 0) or 0)
        if self.op in ("dwconv2d", "avgpool", "maxpool") or not ch:
            ch = int(self.attr("C", 1) or 1)
        oy = int(self.attr("OY", 1) or 1)
        ox = int(self.attr("OX", 1) or 1)
        return max(1, b * ch * oy * ox)

    def output_bytes(self) -> int:
        return self.output_elems() * int(self.attr("elem_bytes", 1) or 1)


@dataclass
class Graph:
    """Topologically ordered DAG of Nodes."""

    name: str
    nodes: list[Node]
    inputs: dict[str, tuple[int, ...]] = field(default_factory=dict)
    outputs: tuple[str, ...] = ()
    attrs: dict = field(default_factory=dict)

    def __post_init__(self):
        self._index = {n.name: i for i, n in enumerate(self.nodes)}

    def node(self, name: str) -> Node:
        return self.nodes[self._index[name]]

    def has(self, name: str) -> bool:
        return name in self._index

    def consumers(self, name: str) -> list[Node]:
        return [n for n in self.nodes if name in n.inputs]

    def edge_bytes(self, producer: str) -> int:
        """Bytes flowing along the edge out of the ``producer`` node,
        sized from its geometry attrs.  Graph inputs return 0: they start
        in the shared home memory, so no cross-module transfer is due.

        Structural passthrough ops (reshape, ...) carry no geometry of
        their own, yet the full producing tensor still flows through them
        — so the chain is walked back to the nearest node that *does*
        declare geometry (pricing such edges at 1 element would let the
        DP move real tensors across modules for free)."""
        cur = producer
        seen: set[str] = set()
        while self.has(cur) and cur not in seen:
            seen.add(cur)
            n = self.node(cur)
            if n.has_geometry() or n.op not in PASSTHROUGH_OPS or not n.inputs:
                return n.output_bytes()
            cur = n.inputs[0]
        return 0

    def single_consumer(self, name: str) -> Node | None:
        cs = self.consumers(name)
        return cs[0] if len(cs) == 1 else None

    def replace_nodes(self, nodes: Sequence[Node]) -> "Graph":
        return Graph(self.name, list(nodes), dict(self.inputs), tuple(self.outputs), dict(self.attrs))

    def topo_check(self) -> bool:
        seen: set[str] = set(self.inputs)
        for n in self.nodes:
            for i in n.inputs:
                if i not in seen:
                    return False
            seen.add(n.name)
        return True

    def total_macs(self) -> float:
        from .workload import prod

        total = 0.0
        for n in self.nodes:
            if n.op in ("conv2d",):
                total += prod(int(n.attr(k, 1)) for k in ("B", "K", "C", "OY", "OX", "FY", "FX"))
            elif n.op in ("dwconv2d",):
                total += prod(int(n.attr(k, 1)) for k in ("B", "C", "OY", "OX", "FY", "FX"))
            elif n.op in ("dense",):
                total += prod(int(n.attr(k, 1)) for k in ("B", "K", "C"))
        return total


# ---------------------------------------------------------------------------
# Transformation passes (paper Sec. IV-A, Table II)
# ---------------------------------------------------------------------------

GraphTransform = Callable[[Graph], Graph]


def apply_transforms(graph: Graph, passes: Iterable[GraphTransform]) -> Graph:
    g = graph
    for p in passes:
        g = p(g)
        assert g.topo_check(), f"pass {getattr(p, '__name__', p)} broke topological order"
    return g


# -- a small library of reusable passes -------------------------------------


def dead_node_elimination(graph: Graph) -> Graph:
    """Remove nodes whose outputs are never consumed (paper Table II)."""
    live: set[str] = set(graph.outputs)
    keep: list[Node] = []
    for n in reversed(graph.nodes):
        if n.name in live:
            keep.append(n)
            live |= set(n.inputs)
    keep.reverse()
    return graph.replace_nodes(keep)


def fold_requant_div(graph: Graph) -> Graph:
    """HW-aware rewrite (paper Table II, GAP9): mul-add-div requant chains
    become a single ``requant`` node implementing (x*M + B) >> S.

    The chain's constants (mul ``scale``, add ``addend``, div ``divisor`` /
    rshift ``shift``) are carried onto the fused node so the requant
    computes the same affine transform the unfolded ops would (rounding
    tightens from the div/rshift semantics to requant's round-half-even —
    that IS the paper's integerization rewrite).  A ``div`` by a
    non-power-of-two cannot become a shift and is left unfolded.
    """
    import math

    nodes: list[Node] = []
    skip: set[str] = set()
    for n in graph.nodes:
        if n.name in skip:
            continue
        if n.op == "mul":
            c1 = graph.single_consumer(n.name)
            if c1 is not None and c1.op == "add":
                c2 = graph.single_consumer(c1.name)
                if c2 is not None and c2.op in ("div", "rshift"):
                    if c2.op == "div":
                        d = float(c2.attr("divisor", 1.0) or 1.0)
                        s = math.log2(d) if d > 0 else -1.0
                        if s < 0 or s != int(s):
                            nodes.append(n)
                            continue  # not a power of two: keep the chain
                        shift = float(int(s))
                    else:
                        shift = float(c2.attr("shift", 0.0) or 0.0)
                    fused = Node(
                        c2.name,
                        "requant",
                        inputs=n.inputs,
                        attrs={
                            **n.attrs,
                            "scale": float(n.attr("scale", 1.0) or 1.0),
                            "addend": float(c1.attr("addend", 0.0) or 0.0),
                            "shift": shift,
                            "folded_from": (n.name, c1.name, c2.name),
                        },
                    )
                    nodes.append(fused)
                    skip |= {c1.name, c2.name}
                    continue
        nodes.append(n)
    return graph.replace_nodes(nodes)


def layout_to(layout: str) -> GraphTransform:
    """Annotate every tensor-op with the activation layout the backend
    kernels require (paper: NHWC for PULP-NN / NE16)."""

    def _pass(graph: Graph) -> Graph:
        return graph.replace_nodes(
            [n.with_attrs(layout=layout) if n.op in ("conv2d", "dwconv2d", "dense", "add", "avgpool", "maxpool") else n for n in graph.nodes]
        )

    _pass.__name__ = f"layout_to_{layout}"
    return _pass


def pad_spatial_to(multiple_of: int, dims: tuple[str, ...] = ("K", "OX")) -> GraphTransform:
    """HW-aware pad pass (paper: DIANA needs K, OX multiples of 16).

    Records the padded sizes in node attrs; the runtime pads/slices around
    the matched segment, as described in the paper (static, no runtime
    overhead for weights).
    """

    def _pass(graph: Graph) -> Graph:
        out = []
        for n in graph.nodes:
            if n.op in ("conv2d", "dense"):
                pads = {}
                for d in dims:
                    v = int(n.attr(d, 0) or 0)
                    if v:
                        pads[f"{d}_padded"] = -(-v // multiple_of) * multiple_of
                out.append(n.with_attrs(**pads) if pads else n)
            else:
                out.append(n)
        return graph.replace_nodes(out)

    _pass.__name__ = f"pad_spatial_to_{multiple_of}"
    return _pass


def integerize(bytes_per_elem: int = 1) -> GraphTransform:
    """Quantize ops/weights to int8 (paper Table II 'Integerization')."""

    def _pass(graph: Graph) -> Graph:
        return graph.replace_nodes([n.with_attrs(elem_bytes=bytes_per_elem) for n in graph.nodes])

    _pass.__name__ = "integerize"
    return _pass
