"""Pattern tables and the pattern matcher (paper Sec. IV-B).

Each execution module lists the operator patterns it can run.  A pattern
is a linear chain of op types (anchor first), an optional constraint on
the matched nodes (layouts, quantization, hyper-parameters — e.g. NE16
rejects the DSCNN 4x10 rectangular first-layer filter), and a builder
turning the matched nodes into a :class:`~repro.core.workload.Workload`
for the DSE engine.

The matcher walks the graph in topological order, follows single-consumer
chains, and — when patterns are nested — keeps the **largest** match
(paper: "node fusion is always convenient").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

from .graph import Graph, Node
from .workload import (
    Workload,
    conv2d_workload,
    dense_workload,
    depthwise_conv2d_workload,
)

__all__ = ["Pattern", "PatternMatch", "match_at", "find_matches", "default_workload"]


ConstraintFn = Callable[[Sequence[Node]], bool]
WorkloadFn = Callable[[Sequence[Node]], Workload]


@dataclass(frozen=True)
class Pattern:
    """A chain of fusable ops an execution module supports."""

    name: str
    ops: tuple[str, ...]  # anchor op first, then the fused epilogue chain
    make_workload: WorkloadFn
    constraint: ConstraintFn | None = None

    def __len__(self) -> int:
        return len(self.ops)


@dataclass(frozen=True)
class PatternMatch:
    pattern: Pattern
    nodes: tuple[Node, ...]

    @property
    def anchor(self) -> Node:
        return self.nodes[0]

    def workload(self) -> Workload:
        return self.pattern.make_workload(self.nodes)


def match_at(graph: Graph, node: Node, pattern: Pattern) -> PatternMatch | None:
    """Try to match ``pattern`` with its anchor at ``node``.

    Follows single-consumer edges so fusion never duplicates work; any
    branch (multi-consumer intermediate) stops the chain, exactly like
    TVM's dominator-based pattern matching in spirit.
    """
    if node.op != pattern.ops[0]:
        return None
    chain = [node]
    cur = node
    for want in pattern.ops[1:]:
        nxt = graph.single_consumer(cur.name)
        if nxt is None or nxt.op != want:
            return None
        chain.append(nxt)
        cur = nxt
    if pattern.constraint is not None and not pattern.constraint(chain):
        return None
    return PatternMatch(pattern, tuple(chain))


def find_matches(graph: Graph, node: Node, patterns: Sequence[Pattern]) -> list[PatternMatch]:
    """All pattern matches anchored at ``node``, longest first."""
    out = [m for p in patterns if (m := match_at(graph, node, p)) is not None]
    out.sort(key=lambda m: -len(m.nodes))
    return out


# ---------------------------------------------------------------------------
# Default workload builders (used by pattern tables and the CPU fallback)
# ---------------------------------------------------------------------------


def _int_attr(n: Node, k: str, default: int = 1) -> int:
    v = n.attr(k, default)
    return int(v if v is not None else default)


def default_workload(node: Node) -> Workload | None:
    """Build a Workload for a single un-fused node (fallback path).

    Returns None for structural ops (reshape, ...) that carry no
    arithmetic worth scheduling — those cost ~0 on any module.  A
    ``concat`` that declares its output geometry (C = sum of the input
    channel counts) is priced as an elementwise copy of its output so
    join graphs get a schedulable fallback segment on every target; a
    geometry-less concat stays structural.
    """
    eb = _int_attr(node, "elem_bytes", 1)
    if node.op == "conv2d":
        return conv2d_workload(
            name=node.name,
            B=_int_attr(node, "B"),
            K=_int_attr(node, "K"),
            C=_int_attr(node, "C"),
            OY=_int_attr(node, "OY"),
            OX=_int_attr(node, "OX"),
            FY=_int_attr(node, "FY"),
            FX=_int_attr(node, "FX"),
            stride=_int_attr(node, "stride"),
            in_bytes=eb,
            w_bytes=eb,
            out_bytes=eb,
            layout=str(node.attr("layout", "NHWC")),
            attrs=dict(node.attrs),
        )
    if node.op == "dwconv2d":
        return depthwise_conv2d_workload(
            name=node.name,
            B=_int_attr(node, "B"),
            C=_int_attr(node, "C"),
            OY=_int_attr(node, "OY"),
            OX=_int_attr(node, "OX"),
            FY=_int_attr(node, "FY"),
            FX=_int_attr(node, "FX"),
            stride=_int_attr(node, "stride"),
            in_bytes=eb,
            w_bytes=eb,
            out_bytes=eb,
            attrs=dict(node.attrs),
        )
    if node.op == "dense":
        return dense_workload(
            name=node.name,
            B=_int_attr(node, "B"),
            K=_int_attr(node, "K"),
            C=_int_attr(node, "C"),
            in_bytes=eb,
            w_bytes=eb,
            out_bytes=eb,
            attrs=dict(node.attrs),
        )
    if node.op == "concat" and not node.has_geometry():
        return None  # no declared output shape: keep the structural path
    if node.op in ("add", "relu", "requant", "bias_add", "mul", "clip", "concat"):
        # elementwise over the *output* geometry (channels = K when the
        # node sits after a conv/dense producer, else C)
        from .workload import LoopDim, Operand, Workload as W

        ch = _int_attr(node, "K", 0) or _int_attr(node, "C", 1)
        elems = _int_attr(node, "B", 1) * ch * _int_attr(node, "OY", 1) * _int_attr(node, "OX", 1)
        loops = (LoopDim("E", max(elems, 1)),)
        ops = (
            Operand("I", dims=("E",), elem_bytes=eb, layout=("E",)),
            Operand("O", dims=("E",), elem_bytes=eb, is_output=True, layout=("E",)),
        )
        return W(node.name, loops, ops, op_type="elementwise", attrs=dict(node.attrs))
    if node.op in ("avgpool", "maxpool"):
        from .workload import LoopDim, Operand, Workload as W

        loops = (
            LoopDim("B", _int_attr(node, "B")),
            LoopDim("C", _int_attr(node, "C")),
            LoopDim("OY", _int_attr(node, "OY")),
            LoopDim("OX", _int_attr(node, "OX")),
            LoopDim("FY", _int_attr(node, "FY"), "reduction"),
            LoopDim("FX", _int_attr(node, "FX"), "reduction"),
        )
        ops = (
            Operand("I", dims=("B", "C", "OY", "OX", "FY", "FX"), elem_bytes=eb, layout=("B", "OY", "OX", "C")),
            Operand("O", dims=("B", "C", "OY", "OX"), elem_bytes=eb, is_output=True, layout=("B", "OY", "OX", "C")),
        )
        return W(node.name, loops, ops, op_type="pool", attrs=dict(node.attrs))
    return None


# Convenience constructors for common CNN pattern tables -------------------


def conv_chain_pattern(name: str, epilogue: tuple[str, ...], constraint: ConstraintFn | None = None) -> Pattern:
    def mk(nodes: Sequence[Node]) -> Workload:
        w = default_workload(nodes[0])
        assert w is not None
        return w.with_attrs(fused=tuple(n.op for n in nodes[1:]))

    return Pattern(name, ("conv2d",) + epilogue, mk, constraint)


def dwconv_chain_pattern(name: str, epilogue: tuple[str, ...], constraint: ConstraintFn | None = None) -> Pattern:
    def mk(nodes: Sequence[Node]) -> Workload:
        w = default_workload(nodes[0])
        assert w is not None
        return w.with_attrs(fused=tuple(n.op for n in nodes[1:]))

    return Pattern(name, ("dwconv2d",) + epilogue, mk, constraint)


def dense_chain_pattern(name: str, epilogue: tuple[str, ...], constraint: ConstraintFn | None = None) -> Pattern:
    def mk(nodes: Sequence[Node]) -> Workload:
        w = default_workload(nodes[0])
        assert w is not None
        return w.with_attrs(fused=tuple(n.op for n in nodes[1:]))

    return Pattern(name, ("dense",) + epilogue, mk, constraint)


def eltwise_chain_pattern(name: str, anchor: str, epilogue: tuple[str, ...] = (), constraint: ConstraintFn | None = None) -> Pattern:
    """Elementwise anchor (add/relu/requant) + optional fused epilogue."""

    def mk(nodes: Sequence[Node]) -> Workload:
        w = default_workload(nodes[0])
        assert w is not None
        return w.with_attrs(fused=tuple(n.op for n in nodes[1:]))

    return Pattern(name, (anchor,) + epilogue, mk, constraint)


def pool_pattern(name: str, op: str = "avgpool", constraint: ConstraintFn | None = None) -> Pattern:
    def mk(nodes: Sequence[Node]) -> Workload:
        w = default_workload(nodes[0])
        assert w is not None
        return w

    return Pattern(name, (op,), mk, constraint)
