"""Declarative hardware abstraction (paper Sec. V, Fig. 4).

A :class:`MatchTarget` holds one or more :class:`ExecutionModule`s.  Each
module declares:

* its memory hierarchy (:class:`MemoryLevel` list, innermost first),
* a pattern table (which operator patterns it can execute — filled in by
  ``repro.core.patterns``),
* a compute model (spatial unrolling + cycle constants), and
* DMA behaviour (sync vs async/double-buffered, per-chunk overheads).

No compiler pass ever hardcodes hardware knowledge: DIANA, GAP9 and the
TPU v5e are all instances of these dataclasses (see ``repro.targets``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

from .workload import Workload, prod

__all__ = [
    "MemoryLevel",
    "SpatialUnrolling",
    "ComputeModel",
    "ExecutionModule",
    "Interconnect",
    "MatchTarget",
]


@dataclass(frozen=True)
class MemoryLevel:
    """One level of a software-managed memory hierarchy.

    ``serves``: operand names this level can hold ("*" = any).  DIANA has a
    dedicated 64 kB weight memory next to the 256 kB activation L1; TPU has
    a single 16 MiB (128 KiB/lane-group usable ~ we model the whole) VMEM.
    ``bandwidth``: bytes/cycle to the level above.
    ``chunk_overhead``: fixed cycles per contiguous chunk transferred
    (paper: 70 cycles on DIANA, 27 on GAP9).
    """

    name: str
    size_bytes: int
    bandwidth: float  # bytes / cycle from the parent level
    serves: tuple[str, ...] = ("*",)
    chunk_overhead: float = 0.0

    def holds(self, operand_name: str) -> bool:
        return "*" in self.serves or operand_name in self.serves


@dataclass(frozen=True)
class SpatialUnrolling:
    """Fixed spatial mapping of loop dims onto the PE array / MXU.

    The paper fixes spatial mappings (already-manufactured targets) and
    searches temporal mappings only; we follow suit.  ``dims`` maps a loop
    dim to the number of PEs along it, e.g. DIANA conv = {K:16, OX:16},
    TPU MXU matmul = {M:128 (rows), N:128 (cols)} per pass.
    """

    dims: Mapping[str, int]
    # Alternative unrollings the module may fall back to (GAP9 cluster
    # "reduced parallelism" rule is implemented in the cost model).
    flexible: bool = False

    def utilization(self, tiles: Mapping[str, int]) -> float:
        """Fraction of PEs busy for a tile (ceil quantization waste)."""
        util = 1.0
        for d, n in self.dims.items():
            t = int(tiles.get(d, 1))
            if t <= 0:
                return 0.0
            util *= t / (math.ceil(t / n) * n)
        return util

    def iterations(self, tiles: Mapping[str, int]) -> int:
        """Temporal iterations to cover a tile with this unrolling."""
        it = 1
        for d, n in self.dims.items():
            it *= math.ceil(int(tiles.get(d, 1)) / n)
        return it


@dataclass(frozen=True)
class ComputeModel:
    """Analytical L_ops model for one module.

    ``cycles_per_iter``: cycles per spatially-parallel MAC wave (DIANA:
    read-in + MAC + write-out = 3).
    ``output_elem_overhead``: extra cycles per *output element wave*
    (DIANA: 23 cycles elementwise + store).
    ``macs_per_pe_cycle``: MACs one PE retires per cycle (SIMD width).
    ``fixed_overhead_cycles``: cycles charged once per workload execution
    *after* the L_ops/L_mem combine (job launch, runtime call overhead) —
    the knob ``repro.calibrate`` fits from measured timings.
    ``custom``: optional full override ``f(workload, tiles, module)->cycles``
    for modules whose published cost model is not PE-array shaped (NE16);
    ``custom_scale`` multiplies its result so calibration can rescale
    opaque models without wrapping the callable (which would defeat the
    schedule-cache keying of ``repro.core.loma``).
    """

    cycles_per_iter: float = 1.0
    output_elem_overhead: float = 0.0
    macs_per_pe_cycle: float = 1.0
    fixed_setup_cycles: float = 0.0
    fixed_overhead_cycles: float = 0.0
    custom: Callable[[Workload, Mapping[str, int], "ExecutionModule"], float] | None = None
    custom_scale: float = 1.0


@dataclass(frozen=True)
class Interconnect:
    """Cross-module data path of a MatchTarget (transfer-cost model).

    When two consecutive graph segments land on *different* execution
    modules, the producer's activations must complete a round trip through
    the shared home level (L2 on the MCUs, HBM on the TPU) before the
    consumer can start: the intra-segment double-buffering credit does not
    survive a module switch.  ``bandwidth`` is the bytes/cycle of that
    shared path; ``hop_latency`` is the fixed synchronisation cost of the
    handoff (DMA reprogramming, cluster fork/join, accelerator job setup)
    paid once per cross-module edge, on top of each module's own
    ``handoff_cycles``.
    """

    bandwidth: float = 8.0  # bytes/cycle through the shared home memory
    hop_latency: float = 100.0  # fixed cycles per cross-module handoff


@dataclass
class ExecutionModule:
    """One HW execution module of a MatchTarget (paper Fig. 4)."""

    name: str
    # innermost level first; the last entry is the "home" level (L2 / HBM)
    memories: tuple[MemoryLevel, ...]
    spatial: Mapping[str, SpatialUnrolling]  # op_type -> unrolling
    compute: ComputeModel
    async_dma: bool = False  # paper: GAP9 max(L_ops, L_mem) vs DIANA sum
    double_buffer: bool = False  # halves usable L1 per operand, enables async
    supported_ops: tuple[str, ...] = ()
    # Pattern table is attached by repro.core.patterns (list of Pattern).
    patterns: list = field(default_factory=list)
    # Constraints: f(workload) -> bool, module-wide (on top of per-pattern)
    constraint: Callable[[Workload], bool] | None = None
    frequency_hz: float = 260e6  # paper experimental setup: 260 MHz
    # Fixed cycles to hand control to / flush this module at a segment
    # boundary where the *other* end of the edge is a different module
    # (NE16 job registers, cluster fork/join, cache flush on the CPU).
    handoff_cycles: float = 0.0
    attrs: dict = field(default_factory=dict)

    # -- helpers --------------------------------------------------------
    @property
    def l1(self) -> MemoryLevel:
        return self.memories[0]

    def levels_for(self, operand: str) -> list[MemoryLevel]:
        return [m for m in self.memories if m.holds(operand)]

    def supports(self, workload: Workload) -> bool:
        if workload.op_type not in self.supported_ops:
            return False
        if self.constraint is not None and not self.constraint(workload):
            return False
        return True

    def spatial_for(self, workload: Workload) -> SpatialUnrolling:
        su = self.spatial.get(workload.op_type)
        if su is None:
            su = self.spatial.get("*", SpatialUnrolling(dims={}))
        return su

    def recalibrated(
        self,
        *,
        compute_scale: float = 1.0,
        mem_scale: float = 1.0,
        fixed_overhead_cycles: float = 0.0,
        tag: str = "",
    ) -> "ExecutionModule":
        """Parameter-override hook for profiling-guided calibration.

        Returns a copy whose declared constants are rescaled so that, for
        any temporal mapping, the predicted breakdown becomes
        ``compute_scale * L_ops``, ``mem_scale * L_mem`` and an extra
        ``fixed_overhead_cycles`` charged after the L_ops/L_mem combine.
        The declared hardware file is never edited; ``tag`` (typically a
        profile fingerprint) lands in ``attrs["calibration"]`` and keys
        the persistent schedule cache (see ``repro.core.loma``).
        """
        import dataclasses

        if compute_scale <= 0 or mem_scale <= 0:
            raise ValueError(
                f"calibration scales must be positive, got compute={compute_scale} mem={mem_scale}"
            )
        if not math.isfinite(fixed_overhead_cycles) or fixed_overhead_cycles < 0:
            raise ValueError(
                f"fixed_overhead_cycles must be finite and >= 0, got {fixed_overhead_cycles}"
            )
        cm = self.compute
        new_cm = dataclasses.replace(
            cm,
            cycles_per_iter=cm.cycles_per_iter * compute_scale,
            output_elem_overhead=cm.output_elem_overhead * compute_scale,
            fixed_setup_cycles=cm.fixed_setup_cycles * compute_scale,
            fixed_overhead_cycles=cm.fixed_overhead_cycles + fixed_overhead_cycles,
            custom_scale=cm.custom_scale * compute_scale,
        )
        mems = tuple(
            dataclasses.replace(
                m,
                bandwidth=m.bandwidth / mem_scale,
                chunk_overhead=m.chunk_overhead * mem_scale,
            )
            for m in self.memories
        )
        new = dataclasses.replace(self)
        new.compute = new_cm
        new.memories = mems
        new.patterns = list(self.patterns)
        new.attrs = dict(self.attrs)
        if tag:
            new.attrs["calibration"] = tag
        return new


@dataclass
class MatchTarget:
    """A SoC / chip: a set of execution modules + a fallback.

    The fallback module models the "un-matched -> TVM default on the main
    CPU" path of the paper; it must support every op type.
    """

    name: str
    modules: list[ExecutionModule]
    fallback: ExecutionModule
    interconnect: Interconnect = field(default_factory=Interconnect)
    attrs: dict = field(default_factory=dict)

    def all_modules(self) -> list[ExecutionModule]:
        return list(self.modules) + [self.fallback]

    def module(self, name: str) -> ExecutionModule:
        for m in self.all_modules():
            if m.name == name:
                return m
        raise KeyError(name)

    def restricted(self, module_names: Sequence[str]) -> "MatchTarget":
        """Target with only a subset of modules enabled (paper Table IV
        ablations: CPU-only / Cluster+CPU / NE16+CPU / Full)."""
        mods = [m for m in self.modules if m.name in module_names]
        return MatchTarget(
            name=f"{self.name}[{'+'.join(module_names) or 'cpu'}]",
            modules=mods,
            fallback=self.fallback,
            interconnect=self.interconnect,
            attrs=dict(self.attrs),
        )

    def recalibrated(
        self, overrides: Mapping[str, object], tag: str = ""
    ) -> "MatchTarget":
        """Target with per-module calibration overrides applied.

        ``overrides`` maps module names to objects (mappings or anything
        with attribute access, e.g. ``repro.calibrate.ModuleCalibration``)
        carrying ``compute_scale`` / ``mem_scale`` / ``fixed_overhead_cycles``.
        Modules without an override are kept as declared.  The target name
        is preserved so registry / lowering consistency checks keep
        holding for calibrated instances.
        """

        def val(ov, key: str, default: float) -> float:
            if isinstance(ov, Mapping):
                return float(ov.get(key, default))
            return float(getattr(ov, key, default))

        def apply(m: ExecutionModule) -> ExecutionModule:
            ov = overrides.get(m.name)
            if ov is None:
                return m
            return m.recalibrated(
                compute_scale=val(ov, "compute_scale", 1.0),
                mem_scale=val(ov, "mem_scale", 1.0),
                fixed_overhead_cycles=val(ov, "fixed_overhead_cycles", 0.0),
                tag=tag,
            )

        new = MatchTarget(
            name=self.name,
            modules=[apply(m) for m in self.modules],
            fallback=apply(self.fallback),
            interconnect=self.interconnect,
            attrs=dict(self.attrs),
        )
        if tag:
            new.attrs["calibration"] = tag
        return new

    def scaled_l1(self, l1_bytes: int) -> "MatchTarget":
        """Target with every module's L1 resized (paper Fig. 9/10 ablation)."""
        import dataclasses

        def scale(m: ExecutionModule) -> ExecutionModule:
            mems = tuple(
                dataclasses.replace(lvl, size_bytes=l1_bytes) if i == 0 else lvl
                for i, lvl in enumerate(m.memories)
            )
            new = dataclasses.replace(m)
            new.memories = mems
            new.patterns = list(m.patterns)
            return new

        return MatchTarget(
            name=f"{self.name}[L1={l1_bytes//1024}kB]",
            modules=[scale(m) for m in self.modules],
            fallback=self.fallback,
            interconnect=self.interconnect,
            attrs=dict(self.attrs),
        )
