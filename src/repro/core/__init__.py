"""repro.core — the MATCH engine: model-aware compilation as data + search.

The paper's primary contribution, reimplemented as a composable library:

* Workload / LoopDim / Operand  — operator loop-nest abstraction
* MemoryLevel / ExecutionModule / MatchTarget — declarative HW models
* search_schedule / ScheduleResult — LOMA temporal-mapping DSE
* evaluate_mapping / CostBreakdown — analytical latency model
* Graph / Node / Pattern / dispatch — graph IR + heterogeneous dispatch
* KernelSchedule / schedule_for_kernel — DSE output -> Pallas BlockSpecs
"""

from .cost_model import (
    CostBreakdown,
    evaluate_mapping,
    operand_traffic,
    tile_chunks,
    tile_working_set,
    transfer_cost,
)
from .dispatcher import MappedGraph, MappedSegment, dispatch
from .graph import Graph, Node, apply_transforms
from .loma import (
    ScheduleCacheWarning,
    SchedulePlanner,
    ScheduleResult,
    TemporalMapping,
    clear_schedule_cache,
    divisors,
    prime_factors,
    search_schedule,
)
from .patterns import Pattern, PatternMatch, default_workload, find_matches
from .schedule import KernelSchedule, schedule_for_kernel, schedule_from_result, tpu_align
from .target import (
    ComputeModel,
    ExecutionModule,
    Interconnect,
    MatchTarget,
    MemoryLevel,
    SpatialUnrolling,
)
from .workload import (
    LoopDim,
    Operand,
    Workload,
    attention_workload,
    conv2d_workload,
    dense_workload,
    depthwise_conv2d_workload,
    matmul_workload,
    scan_workload,
)

__all__ = [
    "CostBreakdown",
    "evaluate_mapping",
    "operand_traffic",
    "tile_chunks",
    "tile_working_set",
    "transfer_cost",
    "MappedGraph",
    "MappedSegment",
    "dispatch",
    "Graph",
    "Node",
    "apply_transforms",
    "ScheduleCacheWarning",
    "SchedulePlanner",
    "ScheduleResult",
    "TemporalMapping",
    "clear_schedule_cache",
    "divisors",
    "prime_factors",
    "search_schedule",
    "Pattern",
    "PatternMatch",
    "default_workload",
    "find_matches",
    "KernelSchedule",
    "schedule_for_kernel",
    "schedule_from_result",
    "tpu_align",
    "ComputeModel",
    "ExecutionModule",
    "Interconnect",
    "MatchTarget",
    "MemoryLevel",
    "SpatialUnrolling",
    "LoopDim",
    "Operand",
    "Workload",
    "attention_workload",
    "conv2d_workload",
    "dense_workload",
    "depthwise_conv2d_workload",
    "matmul_workload",
    "scan_workload",
]
