"""Batched serving engine: slot-based continuous batching (lite).

* Requests queue up; the engine packs up to ``batch_slots`` prompts,
  left-pads to a common prefill length, prefills once, then decodes all
  slots in lock-step with per-slot stop handling.
* Finished slots are refilled from the queue between decode steps
  (continuous batching without paged attention — cache slots are
  per-batch-row, so a new request reuses a finished row by re-prefilling
  its row into the shared cache via the single-row prefill path).  A
  queued prompt longer than the batch's current position cannot join
  lock-step mid-flight; it parks in ``_pending`` and opens the next
  batch instead.
* A request that hits ``max_len`` before ``max_new_tokens`` is returned
  with ``truncated=True`` and a :class:`TruncationWarning` (silently
  under-producing tokens is how decode bugs hide).
* Greedy or temperature sampling.

This is the serving driver used by the decode/long-context dry-run
cells; at pod scale the same engine runs under pjit with the
autosharded rules (weights TP/EP-sharded, cache batch-sharded).
Request-level (whole-graph, non-autoregressive) serving lives in
:mod:`repro.serve`.
"""

from __future__ import annotations

import queue
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.obs.log import MatchWarning
from repro.obs.log import warn as obs_warn

__all__ = ["Request", "ServeEngine", "TruncationWarning"]


class TruncationWarning(MatchWarning):
    """A request ran out of cache headroom (``pos >= max_len``) before
    producing ``max_new_tokens``; its ``truncated`` flag is set."""


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    truncated: bool = False


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.rng = np.random.default_rng(rng_seed)
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._pending: list[Request] = []  # popped but not yet slotted
        self._decode = jax.jit(model.decode_step)
        # serving counters: decode iterations paid and slots recycled —
        # the refill regression test pins their relationship
        self.decode_steps = 0
        self.refills = 0

    def submit(self, req: Request) -> None:
        self._queue.put(req)

    def _pop(self) -> Request | None:
        """One queued request, or None — never empty()-then-get(): with
        concurrent submitters the queue can drain between the two calls,
        and get() would then block forever."""
        try:
            return self._queue.get_nowait()
        except queue.Empty:
            return None

    def _take_batch(self) -> list[Request]:
        out = self._pending[: self.batch_slots]
        del self._pending[: len(out)]
        while len(out) < self.batch_slots:
            r = self._pop()
            if r is None:
                break
            out.append(r)
        return out

    def _next_fitting(self, pos: int) -> Request | None:
        """A waiting request whose prompt fits the lock-step position
        (left-padded to width ``pos``); longer prompts park in
        ``_pending`` for the next batch."""
        for j, r in enumerate(self._pending):
            if len(r.prompt) <= pos:
                return self._pending.pop(j)
        while True:
            r = self._pop()
            if r is None:
                return None
            if len(r.prompt) <= pos:
                return r
            self._pending.append(r)

    def run(self) -> list[Request]:
        """Serve everything currently queued; returns finished requests."""
        finished: list[Request] = []
        while True:
            batch = self._take_batch()
            if not batch:
                return finished
            finished.extend(self._serve_batch(batch))

    # -- single-row prefill path (slot refill) --------------------------
    def _merge_row(self, cache, row_cache, i: int):
        """Write ``row_cache`` (batch 1) into row ``i`` of the shared
        cache.  Batch rows are independent everywhere except the
        position-count leaves, which carry no batch axis and agree by
        construction (both covers span positions ``0..pos-1``)."""
        axes = self.model.cache_axes()
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        row_leaves = jax.tree_util.tree_leaves(row_cache)
        ax_leaves = jax.tree_util.tree_leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple)
        )
        merged = []
        for leaf, row_leaf, ax in zip(leaves, row_leaves, ax_leaves):
            if "batch" in ax:
                b = ax.index("batch")
                src = jnp.take(row_leaf, 0, axis=b)
                merged.append(leaf.at[(slice(None),) * b + (i,)].set(src))
            else:
                merged.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, merged)

    def _refill_slot(self, req: Request, i: int, pos: int, cache):
        """Prefill ``req`` as a single row (left-padded to the lock-step
        width ``pos``), splice it into slot ``i``, and return its first
        sampled token plus the updated cache."""
        row = np.zeros((1, pos), np.int32)
        row[0, pos - len(req.prompt) :] = req.prompt
        logits, row_cache = self.model.prefill(
            self.params, jnp.asarray(row), max_len=self.max_len
        )
        cache = self._merge_row(cache, row_cache, i)
        tok = int(self._sample(logits, [req])[0])
        self.refills += 1
        return tok, cache

    def _serve_batch(self, reqs: list[Request]) -> list[Request]:
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        # left-pad with token 0; positions still 0..plen-1 (pad tokens
        # attend causally but contribute negligibly for smoke-scale tests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt) :] = r.prompt

        logits, cache = self.model.prefill(
            self.params, jnp.asarray(toks), max_len=self.max_len
        )
        pos = plen
        slots = list(reqs)
        live = [True] * B
        served: list[Request] = []
        cur = self._sample(logits, slots)
        for i, r in enumerate(slots):
            r.out_tokens.append(int(cur[i]))

        while True:
            # retire finished slots and refill them from the queue before
            # paying the next lock-step decode; fixpoint, because a
            # refilled request can itself already be satisfied
            changed = True
            while changed:
                changed = False
                for i, r in enumerate(slots):
                    if live[i] and len(r.out_tokens) >= r.max_new_tokens:
                        live[i] = False
                        r.done = True
                        served.append(r)
                        changed = True
                        if pos < self.max_len:
                            nxt = self._next_fitting(pos)
                            if nxt is not None:
                                tok, cache = self._refill_slot(nxt, i, pos, cache)
                                slots[i] = nxt
                                live[i] = True
                                cur[i] = tok
                                nxt.out_tokens.append(tok)
            if not any(live):
                return served
            if pos >= self.max_len:
                trunc = [slots[i].rid for i in range(B) if live[i]]
                for i in range(B):
                    if live[i]:
                        slots[i].truncated = True
                        slots[i].done = True
                        served.append(slots[i])
                obs_warn(
                    f"requests {trunc} hit max_len={self.max_len} at "
                    f"position {pos} before max_new_tokens; returned "
                    "truncated (raise max_len or shorten prompts)",
                    TruncationWarning,
                )
                return served
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(cur, jnp.int32), jnp.int32(pos)
            )
            self.decode_steps += 1
            cur = self._sample(logits, slots)
            pos += 1
            for i, r in enumerate(slots):
                if live[i] and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(cur[i]))

    def _sample(self, logits: jax.Array, reqs: list[Request]) -> np.ndarray:
        lg = np.asarray(logits, np.float32)
        out = np.zeros(len(reqs), np.int32)
        for i, r in enumerate(reqs):
            if r.temperature <= 0:
                out[i] = int(np.argmax(lg[i]))
            else:
                p = lg[i] / r.temperature
                p = np.exp(p - p.max())
                p /= p.sum()
                out[i] = int(self.rng.choice(len(p), p=p))
        return out
