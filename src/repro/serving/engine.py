"""Batched serving engine: slot-based continuous batching (lite).

* Requests queue up; the engine packs up to ``batch_slots`` prompts,
  left-pads to a common prefill length, prefills once, then decodes all
  slots in lock-step with per-slot stop handling.
* Finished slots are refilled from the queue between decode steps
  (continuous batching without paged attention — cache slots are
  per-batch-row, so a new request reuses a finished row by re-prefilling
  its row into the shared cache via the single-row prefill path).
* Greedy or temperature sampling.

This is the serving driver used by the decode/long-context dry-run
cells; at pod scale the same engine runs under pjit with the
autosharded rules (weights TP/EP-sharded, cache batch-sharded).
"""

from __future__ import annotations

import queue
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.rng = np.random.default_rng(rng_seed)
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._decode = jax.jit(model.decode_step)

    def submit(self, req: Request) -> None:
        self._queue.put(req)

    def _take_batch(self) -> list[Request]:
        out = []
        while len(out) < self.batch_slots and not self._queue.empty():
            out.append(self._queue.get())
        return out

    def run(self) -> list[Request]:
        """Serve everything currently queued; returns finished requests."""
        finished: list[Request] = []
        while not self._queue.empty():
            batch = self._take_batch()
            finished.extend(self._serve_batch(batch))
        return finished

    def _serve_batch(self, reqs: list[Request]) -> list[Request]:
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        # left-pad with token 0; positions still 0..plen-1 (pad tokens
        # attend causally but contribute negligibly for smoke-scale tests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt) :] = r.prompt

        logits, cache = self.model.prefill(
            self.params, jnp.asarray(toks), max_len=self.max_len
        )
        pos = plen
        live = [True] * B
        cur = self._sample(logits, reqs)
        for i, r in enumerate(reqs):
            r.out_tokens.append(int(cur[i]))

        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(1, max_new):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(cur, jnp.int32), jnp.int32(pos)
            )
            cur = self._sample(logits, reqs)
            pos += 1
            for i, r in enumerate(reqs):
                if live[i]:
                    if len(r.out_tokens) >= r.max_new_tokens:
                        live[i] = False
                        continue
                    r.out_tokens.append(int(cur[i]))
            if not any(live):
                break
            if pos >= self.max_len:
                break
        for r in reqs:
            r.done = True
        return reqs

    def _sample(self, logits: jax.Array, reqs: list[Request]) -> np.ndarray:
        lg = np.asarray(logits, np.float32)
        out = np.zeros(len(reqs), np.int32)
        for i, r in enumerate(reqs):
            if r.temperature <= 0:
                out[i] = int(np.argmax(lg[i]))
            else:
                p = lg[i] / r.temperature
                p = np.exp(p - p.max())
                p /= p.sum()
                out[i] = int(self.rng.choice(len(p), p=p))
        return out
