"""repro.serving — batched inference engine (prefill + decode slots)."""

from .engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
