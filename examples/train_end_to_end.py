"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps on CPU with the full substrate — data pipeline, AdamW,
checkpointing, preemption guard.

  PYTHONPATH=src python examples/train_end_to_end.py [--steps 200]

~100M params: qwen2.5-family geometry scaled to d_model=512, 8 layers,
vocab 32k (~92M). Loss must drop visibly over the run.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main


def main():
    import tempfile

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if not args.ckpt_dir:
        # fresh dir by default: resuming a stale checkpoint past --steps
        # would make this demo a no-op
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_e2e_ckpt_")

    # ~100M-param dense config via the CLI's smoke override + width bump:
    # we register it inline for the example.
    import repro.configs.qwen2_5_3b as q

    big_smoke = q.CONFIG.replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        vocab=32768, remat="none", name="qwen2.5-100m",
    )  # ~101M params; ~10 s/step on this 1-core container
    q.SMOKE = big_smoke  # the driver resolves --smoke through this

    print(f"params: {big_smoke.n_params()/1e6:.1f}M")
    return train_main(
        [
            "--arch", "qwen2_5_3b", "--smoke",
            "--steps", str(args.steps),
            "--batch", "8", "--seq", "256",
            "--lr", "1e-3",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "10",
        ]
    )


if __name__ == "__main__":
    res = main()
    assert res["final_loss"] < res["first_loss"], res
    print("loss decreased:", res)
