"""Quickstart: the MATCH flow end-to-end, both levels, in ~60 seconds.

  PYTHONPATH=src python examples/quickstart.py

1. paper level — schedule + dispatch an MLPerf-Tiny network on GAP9;
2. TPU level — ask the same engine for a Pallas BlockSpec schedule;
3. train a reduced LM for a few steps and decode from it.
"""

import jax
import jax.numpy as jnp
import numpy as np

# ---- 1. the paper's flow: heterogeneous dispatch on GAP9 ------------------
# Every entry point takes a registered target *name* (repro.targets.registry)
from repro.cnn import resnet8_graph
from repro.core import dispatch
from repro.targets import list_targets

print(f"registered targets: {', '.join(list_targets())}")
g = resnet8_graph()
mapped = dispatch(g, "gap9")
print(mapped.summary())
print(f"-> predicted latency {mapped.latency_s()*1e3:.3f} ms @260 MHz\n")

# ---- 2. the same engine, TPU target: BlockSpecs for a GEMM ----------------
from repro.core import matmul_workload, schedule_for_kernel
from repro.targets import get_target

wl = matmul_workload(M=4096, N=6144, KD=6144)
sched = schedule_for_kernel(
    wl, get_target("tpu_v5e").module("mxu"), align={"M": "sublane", "N": "lane", "KD": "lane"}
)
print(f"TPU GEMM 4096x6144x6144 -> BlockSpec tiles {dict(sched.block)}")
print(f"   grid order {sched.grid_order}, predicted {sched.predicted_cycles:.3g} cycles\n")

# ---- 3. train + decode a reduced assigned architecture --------------------
from repro.configs import get_smoke
from repro.models import LM
from repro.training import OptConfig, make_train_step
from repro.training.optimizer import adamw_init

cfg = get_smoke("recurrentgemma_2b")
model = LM(cfg)
params = model.init(jax.random.key(0))
opt = adamw_init(params)
step = jax.jit(make_train_step(model, OptConfig(lr=2e-3, warmup_steps=2, total_steps=20)))
rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
}
for i in range(5):
    params, opt, m = step(params, opt, batch)
    print(f"train[{cfg.name}] step {i} loss {float(m['loss']):.4f}")

logits, cache = model.prefill(params, batch["tokens"][:1, :16], max_len=32)
toks = [int(jnp.argmax(logits[0]))]
for t in range(4):
    logits, cache = model.decode_step(params, cache, jnp.asarray(toks[-1:], jnp.int32), jnp.int32(16 + t))
    toks.append(int(jnp.argmax(logits[0])))
print(f"decode[{cfg.name}] tokens: {toks}")
