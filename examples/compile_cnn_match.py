"""Paper-faithful compilation example: the DIANA/GAP9 MATCH flow with
transformations, dispatch, backend lowering + static memory planning,
bit-exact execution and per-module breakdown — plus the Fig. 9-style L1
ablation on one network.

  PYTHONPATH=src python examples/compile_cnn_match.py [--json] [--pipeline]
                                                      [--aot] [--trace]
                                                      [--serve] [--slo]

``--json`` additionally prints the machine-readable deployment report
(``CompiledModel.report_dict()``) — the same payload CI and the
calibration fitter consume.  ``--pipeline`` re-dispatches under the
makespan objective and prints the concurrent schedule's Gantt timeline
and per-module occupancy (``repro.pipeline``) next to the sequential
report, then proves the pipelined runtime bit-exact.  ``--aot`` fuses
the whole graph into ONE jitted executable (``repro.backend.aot``),
proves it bit-exact against the per-segment path, and prints the
per-segment vs AOT latency with the measured dispatch overhead.
``--trace`` records the whole MobileNet x gap9 flow — compile-phase
spans, measured per-module runtime lanes, pipelined worker lanes and the
predicted Gantt side-by-side — into one Chrome-trace JSON
(``match_trace.json``, loadable in ui.perfetto.dev) and prints the
predicted-vs-measured drift summary (``repro.obs``).  ``--serve`` fronts
the compiled model with a ``repro.serve.ModelServer`` replica — bounded
admission queue, vmap batch packing, priority-aware rounds — submits a
mixed-priority burst, proves every served output bit-exact with
sequential ``run``, and prints the replica stats that land in
``report_dict()["serve"]``.  ``--slo`` declares burn-rate service-level
objectives on a replica (``repro.obs.SloSpec``), arms the incident
flight recorder, induces an overload (tiny reject-policy queue under a
burst) so the latency/rejection objectives breach, and shows the
resulting Perfetto-loadable incident dump (``match_incident.json``) —
then points at the offline views: ``python -m repro.obs slo`` /
``python -m repro.obs flight``.
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.backend import lower
from repro.cnn import dscnn_graph, init_graph_params
from repro.core import apply_transforms, dispatch
from repro.core.graph import dead_node_elimination, integerize, layout_to
from repro.targets import get_target

# 1. network transformations (paper Table II pipeline)
g = dscnn_graph()
g = apply_transforms(g, [dead_node_elimination, integerize(1), layout_to("NHWC")])

# 2. heterogeneous dispatch on both targets, resolved by registry name
for tgt in (get_target("gap9"), get_target("diana")):
    mapped = dispatch(g, tgt)
    mods = {k: f"{v:.0f}cyc" for k, v in mapped.cycles_by_module().items()}
    print(f"{tgt.name:6s}: {mapped.latency_s()*1e3:7.3f} ms  {mods}")
    first = mapped.module_of("conv_4x10")
    print(f"        4x10-filter first layer -> {first} (paper: not NE16-able)")

# 3. lower the *mapped* graph: fused, memory-planned segment executors,
#    golden-checked bit-exact against the interpreter
params = init_graph_params(g)
x = {k: np.random.default_rng(0).integers(-128, 128, s).astype("float32") for k, s in g.inputs.items()}
mapped = dispatch(g, "gap9")
compiled = lower(mapped)

max_err = compiled.verify(params, x)  # runs the interpreter internally
assert max_err == 0.0, f"compiled path diverged from the interpreter: {max_err}"
out = compiled.run(params, x, timed=True)
print("\ncompiled == interpreted:", {k: v.shape for k, v in out.items()}, f"(max |err| = {max_err})")
print(compiled.report())
if "--json" in sys.argv[1:]:
    print(json.dumps(compiled.report_dict(), indent=2, sort_keys=True))

# 3b. concurrent multi-module schedule + pipelined runtime (PR 5)
if "--pipeline" in sys.argv[1:]:
    from repro.pipeline import PipelinedModel

    mapped_ms = dispatch(g, "gap9", objective="makespan")
    pipelined = PipelinedModel(lower(mapped_ms))
    sched = pipelined.schedule
    print("\n" + sched.gantt())
    print("per-module occupancy:",
          {m: f"{o:.0%}" for m, o in sorted(sched.occupancy().items())})
    print(f"predicted: sequential {mapped_ms.total_cycles():.0f} cyc -> "
          f"makespan {sched.makespan:.0f} cyc ({sched.speedup():.2f}x)")
    err = pipelined.verify(params, x)
    assert err == 0.0, f"pipelined run diverged from sequential: {err}"
    print(f"pipelined == sequential (max |err| = {err})")

# 3c. whole-graph AOT executable (PR 6)
if "--aot" in sys.argv[1:]:
    aot = compiled.to_aot()
    aot.warmup(params, x)  # explicit trace + XLA compile, outside timing
    aot_err = aot.verify(params, x)
    assert aot_err == 0.0, f"AOT diverged from the per-segment path: {aot_err}"
    ov = aot.measure_dispatch_overhead(params, x)
    print(f"\nAOT == per-segment (max |err| = {aot_err})")
    print(f"per-segment path : {ov['per_segment_path_us']:9.1f} us "
          f"({ov['segments']} host dispatches)")
    print(f"one-jit AOT      : {ov['aot_us']:9.1f} us (1 dispatch)")
    print(f"dispatch overhead: {ov['dispatch_overhead_per_segment_us']:9.2f} us/segment "
          f"-> {ov['per_segment_path_us'] / max(ov['aot_us'], 1e-9):.2f}x speedup")
    entry = next(iter(aot._entries.values()))
    print(f"trace {entry.trace_us/1e3:.1f} ms, XLA compile {entry.compile_us/1e3:.1f} ms, "
          f"donation mode {aot.memory!r}")

# 3c'. request-level serving over the compiled pipeline (PR 8)
if "--serve" in sys.argv[1:]:
    from repro.serve import ModelServer

    # fused fidelity keeps the demo fast; the segments/plan are identical
    served_model = lower(mapped, use_pallas=False, band_tiling=False)
    rng = np.random.default_rng(1)
    requests = [
        {k: rng.integers(-128, 128, s).astype("float32") for k, s in g.inputs.items()}
        for _ in range(10)
    ]
    priorities = [1.0, 1.0, 5.0, 1.0, 2.0, 1.0, 5.0, 1.0, 1.0, 2.0]
    with ModelServer(
        served_model, params, batch_slots=4, stream_depth=2, queue_capacity=16
    ) as server:
        server.warmup(requests[0])
        handles = [
            server.submit(r, priority=p) for r, p in zip(requests, priorities)
        ]
        served = [h.result(timeout=120) for h in handles]
    for r, out in zip(requests, served):
        ref = served_model.run(params, r)
        assert all(np.array_equal(np.asarray(ref[k]), np.asarray(out[k])) for k in ref)
    stats = served_model.report_dict()["serve"]
    eng = stats["engine"]
    print(f"\nserved {eng['completed']}/{eng['submitted']} requests bit-exact "
          f"(batch_slots={eng['batch_slots']}, {eng['rounds']} rounds, "
          f"{eng['rejected']} shed)")
    print(f"latency p50 {eng['latency_us']['p50']:.0f} us, "
          f"p99 {eng['latency_us']['p99']:.0f} us; last round order "
          f"{eng['last_round']['rids']} (priority jumps first)")
    print(f"predicted steady state: 1 request per "
          f"{stats['initiation_interval_cycles']:.0f} cyc on "
          f"{stats['bottleneck_module']} -> "
          f"{stats['predicted_requests_per_s']:.0f} req/s, stream speedup "
          f"x{stats['predicted_stream_speedup']:.2f}")

# 3c''. SLOs + incident flight recorder on a serving replica (PR 9)
if "--slo" in sys.argv[1:]:
    import warnings

    from repro import obs
    from repro.serve import ModelServer, QueueFullError

    dump_path = "match_incident.json"
    obs.arm_flight(dump_path)  # first trigger auto-writes the dump
    served_model = lower(mapped, use_pallas=False, band_tiling=False)
    specs = [
        # tight on purpose: the induced overload must breach both
        obs.SloSpec("p99_budget", "latency_p99_us", 2_000.0,
                    description="tail latency budget"),
        obs.SloSpec("rejections", "rejection_rate", 0.10,
                    description="shed-rate bound"),
    ]
    rng = np.random.default_rng(2)
    burst = [
        {k: rng.integers(-128, 128, s).astype("float32") for k, s in g.inputs.items()}
        for _ in range(24)
    ]
    rejected = 0
    with warnings.catch_warnings():
        # the breach warnings are this demo's point; show them once each
        warnings.simplefilter("always", obs.SloBreachWarning)
        with ModelServer(
            served_model, params, batch_slots=2, stream_depth=1,
            queue_capacity=2, policy="reject", replica="demo",
            slo=specs, slo_window_s=60.0,
        ) as server:
            server.warmup(burst[0])
            handles = []
            for r in burst:  # no pacing: the bounded queue must shed
                try:
                    handles.append(server.submit(r))
                except QueueFullError:
                    rejected += 1
            served = [h.result(timeout=120) for h in handles]
        slo = server.stats()["slo"]
    obs.disarm_flight()
    print(f"\nSLO demo: {len(served)} served, {rejected} rejected "
          f"(queue_capacity=2, reject policy)")
    for name, s in sorted(slo["specs"].items()):
        print(f"  {name:12s} {s['kind']:18s} value {s['value']:12.1f} "
              f"vs {s['threshold']:10.1f} burn {s['burn']:5.2f}x -> {s['state']}")
    doc = json.loads(Path(dump_path).read_text())
    print(f"incident dump: {len(doc['traceEvents'])} events -> {dump_path} "
          f"(reason={doc['metadata']['reason']!r}; load in ui.perfetto.dev)")
    print("offline views: python -m repro.obs flight match_incident.json")
    print("               python -m repro.obs slo <report.json>  "
          "(exit 1 on breach — CI-gateable)")

# 3d. end-to-end observability: one Chrome trace of the whole flow (PR 7)
if "--trace" in sys.argv[1:]:
    from repro import obs
    from repro.cnn import mlperf_tiny_networks
    from repro.pipeline import PipelinedModel

    trace_path = "match_trace.json"
    obs.enable_tracing()  # from here on every compile/runtime span records

    mn = mlperf_tiny_networks()["MobileNet"]
    mn_params = init_graph_params(mn)
    mn_x = {
        k: np.random.default_rng(0).integers(-128, 128, s).astype("float32")
        for k, s in mn.inputs.items()
    }
    # compile-phase spans: enumeration, DSE flush, Viterbi DP, makespan
    # re-rank, per-segment lowering routes, memory-planner packing
    mn_mapped = dispatch(mn, "gap9", objective="makespan")
    mn_compiled = lower(mn_mapped)
    mn_compiled.run(mn_params, mn_x)  # warmup (jit compile)
    mn_compiled.run(mn_params, mn_x, timed=True)  # measured run:* lanes
    pipelined = PipelinedModel(mn_compiled)
    pipelined.run(mn_params, mn_x)  # pipeline:* worker lanes
    # predicted Gantt lanes next to the measured ones (pid "predicted")
    obs.trace_predicted_schedule(pipelined.schedule, mn_compiled.target)

    obs.save_trace(trace_path)
    obs.disable_tracing()
    doc = json.loads(Path(trace_path).read_text())
    names = {}
    for e in doc["traceEvents"]:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[(e["pid"], e["tid"])] = e["args"]["name"]
    print(f"\ntrace: {len(doc['traceEvents'])} events -> {trace_path} "
          "(load in ui.perfetto.dev or chrome://tracing)")
    print("lanes:", ", ".join(sorted(set(names.values()))))
    drift = obs.drift_dict("gap9")
    print(f"drift (threshold {drift['threshold']:g}x):")
    for key, grp in sorted(drift["groups"].items()):
        print(f"  {key:14s} geomean {grp['geomean_ratio']:8.2f}x "
              f"over {grp['count']} segments"
              + ("  <- re-fit suggested" if grp["exceeds_threshold"] else ""))

# 4. L1 ablation (Fig. 9/10)
print("\nGAP9 L1 scaling (MACs/cycle):")
for kb in (128, 32, 8):
    tgt = get_target("gap9").scaled_l1(kb * 1024)
    print(f"  L1={kb:4d}kB -> {dispatch(g, tgt).macs_per_cycle():6.2f}")
