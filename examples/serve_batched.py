"""Batched serving example: continuous-batching engine over an SSM
(attention-free => O(1) decode state).

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "mamba2_1_3b", "--smoke", "--requests", "6", "--max-new", "10", "--slots", "3"])
